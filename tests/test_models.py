"""Per-arch smoke tests: reduced configs, forward/loss/train-grad/serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ALL_ARCHS, ShapeConfig, get_config, get_smoke_config
from repro.core import cells
from repro.core.params import init_params
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi

CTX = ShardCtx()


def _batch(cfg, S=16, B=2, kind="train"):
    if cells.is_cell_family(cfg.family):
        S = cfg.gru.seq_len
    shape = ShapeConfig("smoke", seq_len=S, global_batch=B, kind=kind)
    return mapi.concrete_batch(cfg, shape)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_smoke_config(arch)
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    loss, metrics = A.loss_fn(params, cfg, _batch(cfg), CTX)
    assert np.isfinite(float(loss)), (arch, float(loss))
    # random-init loss should be near ln(vocab) for LM families
    if not cells.is_cell_family(cfg.family):
        assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5, float(loss)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    batch = _batch(cfg, kind="prefill")
    logits, cache = A.prefill(params, cfg, batch, CTX)
    assert np.isfinite(np.asarray(logits)).all(), arch
    if cells.is_cell_family(cfg.family):
        # every cell family decodes feature vectors, not token ids
        x = jnp.ones((2, cfg.gru.input_dim), jnp.float32)
        logits2, cache2 = A.decode_step(params, cfg, cache, x, CTX)
    else:
        tok = jnp.zeros((2,), jnp.int32)
        logits2, cache2 = A.decode_step(params, cfg, cache, tok, CTX)
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen2-moe-a2.7b",
                                  "xlstm-125m", "hymba-1.5b"])
def test_grads_finite(arch):
    cfg = get_smoke_config(arch)
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    batch = _batch(cfg, S=8)

    def loss(p):
        return A.loss_fn(p, cfg, batch, CTX)[0]
    g = jax.grad(loss)(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    # at least most params receive gradient signal
    nonzero = sum(np.abs(np.asarray(l)).sum() > 0 for l in leaves)
    assert nonzero > len(leaves) * 0.6, f"{nonzero}/{len(leaves)}"


def test_decode_matches_forward_transformer():
    """Teacher-forced decode == full forward logits (dense transformer)."""
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32",
                                                 param_dtype="float32")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), "float32")
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    from repro.models import transformer
    full = transformer.forward(params, cfg, toks, ctx=CTX)     # (B,S,V)
    logits_p, cache = A.prefill(params, cfg, {"tokens": toks[:, :S - 1]}, CTX)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, S - 2]),
                               rtol=2e-4, atol=2e-4)
    logits_d, _ = A.decode_step(params, cfg, cache, toks[:, S - 1], CTX)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, S - 1]),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_xlstm():
    """Recurrent decode chain reproduces the chunkwise-parallel forward."""
    cfg = get_smoke_config("xlstm-125m").replace(dtype="float32",
                                                 param_dtype="float32")
    from repro.models import xlstm
    params = init_params(xlstm.lm_specs(cfg), jax.random.key(0), "float32")
    B, S = 1, 8
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    full = xlstm.forward(params, cfg, toks, ctx=CTX)
    cache = xlstm.init_cache(cfg, B)
    outs = []
    for t in range(S):
        logits, cache = xlstm.decode_step(params, cfg, cache, toks[:, t], ctx=CTX)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_cache_masks_old_tokens():
    """hymba ring buffer: tokens older than the window do not contribute."""
    cfg = get_smoke_config("hymba-1.5b").replace(dtype="float32",
                                                 param_dtype="float32")
    from repro.models import hymba
    params = init_params(hymba.lm_specs(cfg), jax.random.key(0), "float32")
    B, S = 1, 12   # window is 8 in the smoke config
    toks = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab_size)
    logits, cache = hymba.prefill(params, cfg, toks, ctx=CTX)
    assert np.isfinite(np.asarray(logits)).all()
    # decode continues past the ring boundary without NaN/shape issues
    for t in range(4):
        logits, cache = hymba.decode_step(params, cfg, cache,
                                          toks[:, t], ctx=CTX)
        assert np.isfinite(np.asarray(logits)).all()


def test_mlstm_chunkwise_equals_recurrent():
    from repro.models.xlstm import (mlstm_chunkwise, mlstm_init_state,
                                    mlstm_recurrent_step)
    B, NH, S, DH = 2, 3, 12, 8
    ks = jax.random.split(jax.random.key(4), 5)
    q = jax.random.normal(ks[0], (B, NH, S, DH))
    k = jax.random.normal(ks[1], (B, NH, S, DH))
    v = jax.random.normal(ks[2], (B, NH, S, DH))
    ig = jax.random.normal(ks[3], (B, NH, S))
    fg = jax.random.normal(ks[4], (B, NH, S)) + 1.0
    h_chunk, state_c = mlstm_chunkwise(q, k, v, ig, fg,
                                       mlstm_init_state(B, NH, DH), chunk=4)
    state = mlstm_init_state(B, NH, DH)
    outs = []
    for t in range(S):
        h, state = mlstm_recurrent_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                        ig[:, :, t], fg[:, :, t], state)
        outs.append(h)
    h_rec = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_rec),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(state_c, state):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_full_configs_match_assignment():
    spec = {
        "xlstm-125m": (12, 768, 4, 4, 50304),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 151936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "qwen3-0.6b": (28, 1024, 16, 8, 151936),
        "command-r-35b": (40, 8192, 64, 8, 256000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 200064),
        "qwen2.5-3b": (36, 2048, 16, 2, 151936),
        "whisper-large-v3": (32, 1280, 20, 20, 51866),
        "hymba-1.5b": (32, 1600, 25, 5, 32001),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 32000),
    }
    for arch, (L, d, H, kv, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.vocab_size) == (L, d, H, kv, V), arch
    assert get_config("qwen2-moe-a2.7b").moe.num_experts == 60
    assert get_config("qwen2-moe-a2.7b").moe.top_k == 4
    assert get_config("qwen3-moe-235b-a22b").moe.num_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8
    assert get_config("hymba-1.5b").ssm.state_dim == 16
